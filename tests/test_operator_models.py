"""Tests for the neural-operator model zoo (FNO/TFNO/SFNO/GINO/U-Net)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FULL, get_policy
from repro.models import (
    FNOConfig,
    GINOConfig,
    SFNOConfig,
    UNetConfig,
    fno_apply,
    gino_apply,
    init_fno,
    init_gino,
    init_sfno,
    init_unet,
    param_count,
    sfno_apply,
    unet_apply,
)

jax.config.update("jax_platform_name", "cpu")


class TestFNO:
    @pytest.mark.parametrize("fact", ["dense", "cp", "tucker"])
    def test_forward_shapes(self, fact):
        cfg = FNOConfig(
            in_channels=3, out_channels=1, hidden_channels=16,
            lifting_channels=24, projection_channels=24, n_layers=2,
            modes=(4, 4), factorization=fact,
        )
        params = init_fno(jax.random.PRNGKey(0), cfg)
        x = jnp.ones((2, 3, 16, 16))
        y = fno_apply(params, x, cfg, FULL)
        assert y.shape == (2, 1, 16, 16)
        assert np.isfinite(np.asarray(y)).all()

    def test_mixed_policy_close_to_full(self):
        cfg = FNOConfig(
            in_channels=1, out_channels=1, hidden_channels=16,
            lifting_channels=16, projection_channels=16, n_layers=2, modes=(4, 4),
        )
        params = init_fno(jax.random.PRNGKey(1), cfg)
        x = jnp.asarray(np.random.RandomState(0).randn(2, 1, 16, 16), jnp.float32)
        y_full = np.asarray(fno_apply(params, x, cfg, FULL))
        y_half = np.asarray(fno_apply(params, x, cfg, get_policy("mixed_fno_bf16")), np.float32)
        rel = np.linalg.norm(y_half - y_full) / (np.linalg.norm(y_full) + 1e-9)
        assert rel < 0.25, rel  # tanh + half storage changes the net slightly

    def test_train_step_reduces_loss(self):
        """End-to-end sanity: a few SGD steps reduce the fit loss."""
        cfg = FNOConfig(
            in_channels=1, out_channels=1, hidden_channels=12,
            lifting_channels=12, projection_channels=12, n_layers=2, modes=(4, 4),
        )
        params = init_fno(jax.random.PRNGKey(2), cfg)
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.randn(4, 1, 16, 16), jnp.float32)
        t = jnp.asarray(rng.randn(4, 1, 16, 16), jnp.float32) * 0.1

        def loss_fn(p):
            y = fno_apply(p, x, cfg, FULL)
            return jnp.mean((y - t) ** 2)

        loss0 = float(loss_fn(params))
        g = jax.grad(loss_fn)
        for _ in range(5):
            grads = g(params)
            params = jax.tree_util.tree_map(lambda p, gr: p - 0.05 * gr, params, grads)
        assert float(loss_fn(params)) < loss0

    def test_resolution_invariance(self):
        """Same params run at 16x16 and 32x32 (discretisation convergence)."""
        cfg = FNOConfig(
            in_channels=1, out_channels=1, hidden_channels=8,
            lifting_channels=8, projection_channels=8, n_layers=1, modes=(4, 4),
        )
        params = init_fno(jax.random.PRNGKey(4), cfg)
        for n in (16, 32):
            y = fno_apply(params, jnp.ones((1, 1, n, n)), cfg, FULL)
            assert y.shape == (1, 1, n, n)

    def test_cp_fewer_params_than_dense(self):
        mk = lambda f: init_fno(
            jax.random.PRNGKey(0),
            FNOConfig(hidden_channels=32, n_layers=2, modes=(8, 8), factorization=f),
        )
        assert param_count(mk("cp")) < param_count(mk("dense"))


class TestSFNO:
    def test_forward_shapes(self):
        cfg = SFNOConfig(
            in_channels=3, out_channels=3, hidden_channels=8, n_layers=2,
            nlat=16, nlon=32, lmax=8, mmax=8,
            lifting_channels=8, projection_channels=8,
        )
        params = init_sfno(jax.random.PRNGKey(0), cfg)
        x = jnp.ones((2, 3, 16, 32))
        y = sfno_apply(params, x, cfg, FULL)
        assert y.shape == (2, 3, 16, 32)
        assert np.isfinite(np.asarray(y)).all()

    def test_mixed_policy_finite(self):
        cfg = SFNOConfig(
            in_channels=1, out_channels=1, hidden_channels=8, n_layers=1,
            nlat=16, nlon=32, lmax=8, mmax=8,
            lifting_channels=8, projection_channels=8,
        )
        params = init_sfno(jax.random.PRNGKey(1), cfg)
        x = jnp.asarray(np.random.RandomState(2).randn(1, 1, 16, 32) * 100, jnp.float32)
        y = sfno_apply(params, x, cfg, get_policy("mixed_fno_fp16"))
        assert np.isfinite(np.asarray(y, np.float32)).all()


class TestGINO:
    def _batch(self, B=2, N=64, G=4, k=4, Nq=32):
        rng = np.random.RandomState(0)
        return {
            "points": jnp.asarray(rng.rand(B, N, 3), jnp.float32),
            "feats": jnp.asarray(rng.randn(B, N, 1), jnp.float32),
            "enc_idx": jnp.asarray(rng.randint(0, N, (B, G ** 3, k))),
            "enc_mask": jnp.asarray(rng.rand(B, G ** 3, k) > 0.3, jnp.float32),
            "query": jnp.asarray(rng.rand(B, Nq, 3), jnp.float32),
            "dec_idx": jnp.asarray(rng.randint(0, G ** 3, (B, Nq, k))),
            "dec_mask": jnp.ones((B, Nq, k), jnp.float32),
        }

    def test_forward_shapes(self):
        from repro.models.fno import FNOConfig

        cfg = GINOConfig(
            hidden=8, latent_grid=4, k_neighbors=4,
            fno=FNOConfig(
                in_channels=8, out_channels=8, hidden_channels=8,
                lifting_channels=8, projection_channels=8, n_layers=1,
                modes=(2, 2, 2), positional_embedding=False,
            ),
        )
        params = init_gino(jax.random.PRNGKey(0), cfg)
        batch = self._batch(G=4, k=4)
        y = gino_apply(params, batch, cfg, FULL)
        assert y.shape == (2, 32, 1)
        assert np.isfinite(np.asarray(y)).all()


class TestUNet:
    def test_forward_shapes(self):
        cfg = UNetConfig(in_channels=3, out_channels=1, base_width=8, depth=2)
        params = init_unet(jax.random.PRNGKey(0), cfg)
        x = jnp.ones((2, 3, 32, 32))
        y = unet_apply(params, x, cfg, FULL)
        assert y.shape == (2, 1, 32, 32)
        assert np.isfinite(np.asarray(y)).all()

    def test_amp_policy(self):
        cfg = UNetConfig(in_channels=1, out_channels=1, base_width=8, depth=2)
        params = init_unet(jax.random.PRNGKey(1), cfg)
        x = jnp.ones((1, 1, 16, 16))
        y = unet_apply(params, x, cfg, get_policy("amp_bf16"))
        assert np.isfinite(np.asarray(y, np.float32)).all()
