"""``python -m repro.tune`` — tune / validate / report.

  tune      enumerate legal tiles per (family, shape, dtype) key, time
            each candidate on the backend, gate the fastest through the
            einsum oracle, persist winners to the calibration state
            (atomic write).  ``--smoke`` shrinks shapes and candidate
            counts so CI exercises the full loop in interpret mode.
  validate  re-run the oracle gate over every entry of an existing
            state file; exit 1 if any entry fails (``--prune`` rewrites
            the file without the failures).  ``--perturb X`` injects a
            scaled violation first — the self-check proving the gate
            rejects wrong kernels rather than passing vacuously.
  report    human-readable table: tiles, walls, GB/s, roofline
            fraction, validation + staleness per entry.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

from . import cache as cache_mod
from . import oracle, space
from .cache import CalibrationCache, CalibrationError
from .measure import default_interpret, measure
from repro.kernels.spectral_contract import KERNEL_VERSION

DEFAULT_STATE = os.path.join("benchmarks", "results",
                             "calibration_state.json")

#: production tuning keys: the bench_kernels cases plus the SFNO
#: l-shared family, at the registry policies' half storage dtype
DEFAULT_KEYS = (
    ("dense", (4, 32, 32, 144), "bfloat16"),
    ("dense-fused", (4, 32, 32, 144), "bfloat16"),
    ("dense", (2, 16, 16, 216), "bfloat16"),
    ("cp", (4, 32, 32, 16, 144), "bfloat16"),
    ("lshared", (2, 8, 8, 12, 9), "bfloat16"),
    # the fused megakernel at bench_kernels' fused-2d case:
    # (B, I, O, *spatial, *modes)
    ("spectral_fused", (4, 16, 16, 24, 24, 6, 6), "bfloat16"),
)

#: CI smoke keys: tiny shapes, every family still covered
SMOKE_KEYS = (
    ("dense", (2, 8, 8, 40), "bfloat16"),
    ("dense-fused", (2, 8, 8, 40), "bfloat16"),
    ("cp", (2, 8, 8, 4, 40), "bfloat16"),
    ("lshared", (2, 8, 8, 12, 9), "bfloat16"),
    ("spectral_fused", (2, 4, 4, 12, 9, 3, 3), "bfloat16"),
)


def _state_path(args) -> str:
    return (args.state or os.environ.get(cache_mod.ENV_VAR)
            or DEFAULT_STATE)


def _entry_from(cand: space.Candidate, perf: dict, verdict: dict,
                backend: str) -> dict:
    return {
        "family": cand.family,
        "shape": list(cand.shape),
        "dtype": cand.dtype,
        "backend": backend,
        "kernel_version": KERNEL_VERSION,
        "block_fwd": cand.block_fwd,
        "block_bwd": cand.block_bwd,
        "wall_us": round(perf["wall_us"], 2),
        "bytes_moved": perf["bytes_moved"],
        "gbps": perf["gbps"],
        "roofline_fraction": perf["roofline_fraction"],
        "interpret": perf["interpret"],
        "validated": True,
        "max_err": verdict["max_err"],
        "budget_min": verdict["budget_min"],
    }


def cmd_tune(args) -> int:
    interpret = (default_interpret() if args.interpret is None
                 else args.interpret)
    backend = jax.default_backend()
    keys = SMOKE_KEYS if args.smoke else DEFAULT_KEYS
    limit = args.limit if args.limit is not None else (4 if args.smoke
                                                      else None)
    iters = args.iters if args.iters is not None else (1 if args.smoke
                                                      else 5)
    path = _state_path(args)
    try:
        state = cache_mod.load(path)
    except CalibrationError:
        state = CalibrationCache(entries={}, backend=backend)
    state.kernel_version = KERNEL_VERSION
    state.backend = backend

    n_admitted = 0
    for family, shape, dtype in keys:
        cands = space.candidates(family, shape, dtype, limit=limit)
        timed = []
        for c in cands:
            perf = measure(c, interpret=interpret, iters=iters,
                           warmup=args.warmup, seed=args.seed)
            timed.append((perf["wall_us"], c, perf))
            print(f"  {family} {tuple(shape)} {dtype} "
                  f"fwd={c.block_fwd} bwd={c.block_bwd}: "
                  f"{perf['wall_us']:.1f} us  {perf['gbps']:.2f} GB/s")
        timed.sort(key=lambda t: t[0])
        # admission: fastest candidate that survives the oracle gate.
        # A candidate failing the Thm 3.2 budget is never written — a
        # mistuned-but-wrong kernel is unrepresentable in the cache.
        admitted = None
        for wall, c, perf in timed:
            verdict = oracle.check(c, interpret=interpret, seed=args.seed)
            if verdict["passed"]:
                admitted = (c, perf, verdict)
                break
            print(f"  REFUSED fwd={c.block_fwd} bwd={c.block_bwd}: "
                  f"max_err {verdict['max_err']:.3e} exceeds budget "
                  f"(worst excess {verdict['worst_excess']:.3e})")
        if admitted is None:
            print(f"  {family} {tuple(shape)} {dtype}: no candidate "
                  f"passed the oracle — key left uncalibrated",
                  file=sys.stderr)
            continue
        c, perf, verdict = admitted
        state.put(_entry_from(c, perf, verdict, backend))
        n_admitted += 1
        print(f"  ADMIT {family} {tuple(shape)} {dtype}: "
              f"fwd={c.block_fwd} bwd={c.block_bwd} "
              f"({perf['wall_us']:.1f} us, max_err "
              f"{verdict['max_err']:.3e} <= budget)")
    out = cache_mod.save(state, path)
    print(f"wrote {n_admitted} calibrated entr"
          f"{'y' if n_admitted == 1 else 'ies'} -> {out}")
    return 0 if n_admitted else 1


def _cand_of(ent: dict) -> space.Candidate:
    return space.Candidate(
        family=ent["family"], shape=tuple(ent["shape"]),
        dtype=ent["dtype"], block_fwd=int(ent["block_fwd"]),
        block_bwd=int(ent["block_bwd"]))


def cmd_validate(args) -> int:
    path = _state_path(args)
    try:
        state = cache_mod.load(path)
    except CalibrationError as e:
        print(f"validate: {e}", file=sys.stderr)
        return 2
    interpret = (default_interpret() if args.interpret is None
                 else args.interpret)
    failures, stale, checked = [], [], 0
    for key, ent in sorted(state.entries.items()):
        if not cache_mod._entry_ok(ent):
            failures.append((key, "corrupt entry (structural)"))
            continue
        if ent.get("kernel_version") != KERNEL_VERSION:
            stale.append((key, f"kernel_version {ent.get('kernel_version')}"
                               f" != {KERNEL_VERSION}"))
            continue
        verdict = oracle.check(_cand_of(ent), interpret=interpret,
                               seed=args.seed, perturb=args.perturb)
        checked += 1
        if not verdict["passed"]:
            failures.append(
                (key, f"max_err {verdict['max_err']:.3e} exceeds the "
                      f"Thm 3.2 budget (worst excess "
                      f"{verdict['worst_excess']:.3e})"))
    for key, why in stale:
        print(f"STALE  {key}: {why} (entry is never served)")
    for key, why in failures:
        print(f"REJECT {key}: {why}")
    print(f"validate: {checked} checked, {len(failures)} rejected, "
          f"{len(stale)} stale")
    if failures and args.prune:
        for key, _ in failures:
            state.entries.pop(key, None)
        cache_mod.save(state, path)
        print(f"pruned {len(failures)} entries -> {path}")
    return 1 if failures else 0


def cmd_report(args) -> int:
    path = _state_path(args)
    try:
        state = cache_mod.load(path)
    except CalibrationError as e:
        print(f"report: {e}", file=sys.stderr)
        return 2
    backend = jax.default_backend()
    print(f"calibration state: {path}")
    print(f"  format {cache_mod.FORMAT_VERSION}, tuned at kernel_version "
          f"{state.kernel_version} on backend {state.backend!r} "
          f"(current: {KERNEL_VERSION} on {backend!r})")
    hdr = (f"{'key':<42} {'fwd':>4} {'bwd':>4} {'wall_us':>9} "
           f"{'GB/s':>8} {'roof%':>6} {'ok':>3}")
    print(hdr)
    print("-" * len(hdr))
    for key, ent in sorted(state.entries.items()):
        live = (cache_mod._entry_ok(ent)
                and ent.get("validated", False)
                and ent.get("kernel_version") == KERNEL_VERSION
                and ent.get("backend") == backend)
        flag = "ok" if live else "--"
        print(f"{key:<42} {ent.get('block_fwd', '?'):>4} "
              f"{ent.get('block_bwd', '?'):>4} "
              f"{ent.get('wall_us', float('nan')):>9.1f} "
              f"{ent.get('gbps', float('nan')):>8.2f} "
              f"{100 * ent.get('roofline_fraction', 0):>5.1f}% "
              f"{flag:>3}")
    if args.json:
        print(json.dumps(state.to_json(), indent=2, sort_keys=True))
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.tune",
                                description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    def common(sp):
        sp.add_argument("--state", default=None,
                        help=f"calibration-state path (default: "
                             f"${cache_mod.ENV_VAR} or {DEFAULT_STATE})")
        sp.add_argument("--seed", type=int, default=0)
        g = sp.add_mutually_exclusive_group()
        g.add_argument("--interpret", dest="interpret",
                       action="store_true", default=None,
                       help="force interpret mode (default: auto — "
                            "interpret off TPU)")
        g.add_argument("--no-interpret", dest="interpret",
                       action="store_false")

    t = sub.add_parser("tune", help="search, time, gate, persist")
    common(t)
    t.add_argument("--smoke", action="store_true",
                   help="tiny shapes + capped candidates (CI)")
    t.add_argument("--iters", type=int, default=None,
                   help="timing samples per candidate (median)")
    t.add_argument("--warmup", type=int, default=1)
    t.add_argument("--limit", type=int, default=None,
                   help="cap candidates per key")
    t.set_defaults(fn=cmd_tune)

    v = sub.add_parser("validate", help="re-run the oracle over a state")
    common(v)
    v.add_argument("--perturb", type=float, default=0.0,
                   help="inject a scaled budget violation (self-check; "
                        ">1 must reject every entry)")
    v.add_argument("--prune", action="store_true",
                   help="rewrite the state without rejected entries")
    v.set_defaults(fn=cmd_validate)

    r = sub.add_parser("report", help="print the state as a table")
    common(r)
    r.add_argument("--json", action="store_true")
    r.set_defaults(fn=cmd_report)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
