"""Typed metrics registry: Counter / Gauge / Histogram with a bounded
label space and one ``snapshot()`` that is the export source for every
engine/trainer ``stats()`` surface.

Naming convention (enforced): ``repro_<subsystem>_<name>`` — lowercase,
``[a-z0-9_]``.  Labels are explicit keyword pairs at instrument lookup
(``counter("repro_kernels_calls_total", family="dense")``); the registry
caps distinct label sets per metric name (``MAX_LABEL_SETS``) so a bug
can never grow unbounded cardinality, which is the classic way a metrics
layer takes a service down.

Histograms use *fixed* bucket edges declared at creation — snapshots are
therefore constant-shape, and the Prometheus exposition
(:mod:`repro.obs.export`) can emit cumulative ``_bucket{le=...}`` series
without remembering state.

Two bridges tie the existing accounting surfaces in:

* ``publish(prefix, mapping)`` flattens a nested ``stats()`` dict into
  gauges ``repro_<prefix>_<path>`` (numbers only; strings/None are
  skipped).  ``EngineBase.stats()`` and the Trainer publish through it,
  so the registry snapshot is the single machine-readable source while
  the dict return stays for callers and tests.
* ``register_external(name, snapshot_fn, reset_fn)`` adopts counters
  that live elsewhere (``kernels.ops.tile_resolution_stats``): they show
  up under ``snapshot()["external"]`` and ``reset()`` resets them too —
  the stats-counter-hygiene contract bench scripts rely on between
  warmup and measurement legs.
"""
from __future__ import annotations

import re
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^repro(_[a-z0-9]+)+$")

#: distinct label sets allowed per metric name before lookups raise
MAX_LABEL_SETS = 64

#: default histogram bucket edges (ms-scale latencies)
DEFAULT_EDGES_MS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
                    500.0, 1000.0, 2000.0, 5000.0)


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(
            f"metric name {name!r} violates the repro_<subsystem>_<name> "
            f"convention (lowercase, [a-z0-9_])")
    return name


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing within a reset window."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Fixed-edge histogram: per-bucket counts plus sum/count."""

    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: Sequence[float]):
        self.edges = tuple(float(e) for e in edges)
        if list(self.edges) != sorted(set(self.edges)):
            raise ValueError(f"bucket edges must be strictly increasing, "
                             f"got {edges}")
        self.counts = [0] * (len(self.edges) + 1)   # +inf overflow bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, e in enumerate(self.edges):
            if v <= e:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def _zero(self) -> None:
        self.counts = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0


class MetricsRegistry:
    """Instrument store keyed by (name, sorted label pairs)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, tuple], Counter] = {}
        self._gauges: Dict[Tuple[str, tuple], Gauge] = {}
        self._hists: Dict[Tuple[str, tuple], Histogram] = {}
        self._label_sets: Dict[str, set] = {}
        self._external: Dict[str, Tuple[Callable[[], Any],
                                        Optional[Callable[[], None]]]] = {}

    # -- instrument lookup ---------------------------------------------------
    def _key(self, name: str, labels: Dict[str, str]) -> Tuple[str, tuple]:
        _check_name(name)
        lk = _label_key(labels)
        seen = self._label_sets.setdefault(name, set())
        if lk not in seen:
            if len(seen) >= MAX_LABEL_SETS:
                raise ValueError(
                    f"metric {name!r} exceeded {MAX_LABEL_SETS} distinct "
                    f"label sets — unbounded label cardinality is a bug")
            seen.add(lk)
        return (name, lk)

    def counter(self, name: str, **labels) -> Counter:
        key = self._key(name, labels)
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = self._key(name, labels)
        with self._lock:
            g = self._gauges.get(key)
            if g is None:
                g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, edges: Sequence[float] = DEFAULT_EDGES_MS,
                  **labels) -> Histogram:
        key = self._key(name, labels)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = Histogram(edges)
            elif h.edges != tuple(float(e) for e in edges):
                raise ValueError(
                    f"histogram {name!r} re-declared with different edges")
        return h

    # -- stats() bridge ------------------------------------------------------
    def publish(self, prefix: str, mapping: Dict[str, Any]) -> None:
        """Flatten a nested stats dict into gauges
        ``repro_<prefix>_<path>``; non-numeric leaves are skipped."""
        base = f"repro_{prefix}".lower()

        def walk(prefix: str, m: Dict[str, Any]):
            for k, v in m.items():
                part = re.sub(r"[^a-z0-9]+", "_",
                              str(k).lower()).strip("_") or "x"
                full = f"{prefix}_{part}"
                if isinstance(v, dict):
                    walk(full, v)
                elif isinstance(v, bool):
                    self.gauge(full).set(float(v))
                elif isinstance(v, (int, float)):
                    self.gauge(full).set(float(v))

        walk(base, mapping)

    def register_external(self, name: str,
                          snapshot_fn: Callable[[], Any],
                          reset_fn: Optional[Callable[[], None]] = None
                          ) -> None:
        """Adopt a counter surface that lives outside the registry:
        ``snapshot()`` includes its ``snapshot_fn()`` output and
        ``reset()`` calls its ``reset_fn`` (the hygiene contract)."""
        _check_name(name)
        self._external[name] = (snapshot_fn, reset_fn)

    # -- snapshot / reset ----------------------------------------------------
    @staticmethod
    def _series(key: Tuple[str, tuple]) -> str:
        name, labels = key
        if not labels:
            return name
        inner = ",".join(f'{k}="{v}"' for k, v in labels)
        return f"{name}{{{inner}}}"

    def snapshot(self) -> Dict[str, Any]:
        """The single source for engine/trainer stats export: every
        instrument's current value, JSON-friendly and stable-ordered."""
        with self._lock:
            out: Dict[str, Any] = {
                "counters": {self._series(k): c.value
                             for k, c in sorted(self._counters.items())},
                "gauges": {self._series(k): g.value
                           for k, g in sorted(self._gauges.items())},
                "histograms": {
                    self._series(k): {
                        "edges": list(h.edges),
                        "counts": list(h.counts),
                        "sum": h.sum,
                        "count": h.count,
                    }
                    for k, h in sorted(self._hists.items())
                },
            }
        ext = {}
        for name, (snap, _reset) in sorted(self._external.items()):
            try:
                ext[name] = snap()
            except Exception as e:  # snapshot must never take a run down
                ext[name] = {"error": repr(e)}
        if ext:
            out["external"] = ext
        return out

    def reset(self) -> None:
        """Zero every instrument and reset registered external counters
        (bench scripts call this between warmup and measurement)."""
        with self._lock:
            for c in self._counters.values():
                c.value = 0.0
            for g in self._gauges.values():
                g.value = 0.0
            for h in self._hists.values():
                h._zero()
        for _name, (_snap, reset_fn) in self._external.items():
            if reset_fn is not None:
                reset_fn()

    def clear(self) -> None:
        """Drop every instrument and external registration (tests)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._label_sets.clear()
        self._external.clear()


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry every subsystem registers into."""
    return _REGISTRY


def metric_names(snapshot: Optional[Dict[str, Any]] = None) -> List[str]:
    """Sorted series names of a snapshot (the schema-golden observable:
    key drift in any ``stats()`` surface changes this list)."""
    snap = snapshot if snapshot is not None else _REGISTRY.snapshot()
    names: List[str] = []
    for kind in ("counters", "gauges", "histograms"):
        names.extend(snap.get(kind, {}))
    names.extend(snap.get("external", {}))
    return sorted(names)
