"""Pallas TPU RMSNorm kernel (memory-bound substrate op for the LM pool).

RMSNorm is bandwidth-bound: one read + one write of the activation, so the
kernel's job is simply to keep the row tile resident in VMEM and fuse the
reduction with the scale — XLA does this well already, but the Pallas
version pins the block layout (rows × full feature dim) so fusion survives
surrounding sharding constraints, and demonstrates the reduction-in-f32 /
storage-in-half recipe that the paper's AMP blocklist mandates for
normalisation ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # (TR, D) — reduce in f32 (AMP rule)
    w = w_ref[...].astype(jnp.float32)  # (D,)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * w[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: (N, D) rows to normalise; w: (D,) scale. Returns (N, D)."""
    N, D = x.shape
    pad = (-N) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Np = N + pad
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(Np // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda _r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, D), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:N]
