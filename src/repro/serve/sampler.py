"""Sampling API for the serve engines: greedy / temperature / top-k /
top-p with per-request parameters and explicit jax PRNG keys.

Sampling is a *precision site* like everything else in the serving
stack: the engine resolves ``serve/sampler`` through the rule table and
hands the resolved :class:`~repro.precision.SitePrecision` in, so the
softmax/filtering math runs at a declared format (pinned f32 in the
shared base table — the AMP-blocklist treatment reductions get
everywhere else in this repo) instead of inheriting whatever dtype the
logits happened to arrive in.

Determinism contract: ``sample_token`` is a pure function of
``(logits, params, key)``.  The engine derives per-request keys with
``request_key`` (fold uid, then the generation index), so a fixed engine
seed replays identical samples regardless of slot assignment or tick
interleaving — the property the continuous-batching tests pin down.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs.

    temperature: 0 (or negative) => greedy argmax; PRNG key unused.
    top_k:       keep only the k highest logits (0 => off).
    top_p:       nucleus sampling — keep the smallest prefix of the
                 descending-probability ordering with cumulative mass
                 >= top_p (1.0 => off).  Always keeps at least the
                 argmax, so top_p -> 0 degrades to greedy.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


GREEDY = SamplingParams()


def apply_top_k(logits: jnp.ndarray, k: int) -> jnp.ndarray:
    """Mask everything below the k-th largest logit to -inf."""
    kth = jax.lax.top_k(logits, min(k, logits.shape[-1]))[0][..., -1:]
    return jnp.where(logits < kth, -jnp.inf, logits)


def apply_top_p(logits: jnp.ndarray, p: float) -> jnp.ndarray:
    """Nucleus filter: keep the minimal descending-probability prefix
    whose cumulative mass reaches ``p`` (the head token always stays)."""
    order = jnp.argsort(-logits)
    sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # token i is kept while the mass *before* it is < p
    keep_sorted = (cum - probs) < p
    keep = jnp.zeros_like(keep_sorted).at[order].set(keep_sorted)
    return jnp.where(keep, logits, -jnp.inf)


def sample_token(
    logits: jnp.ndarray,
    params: SamplingParams = GREEDY,
    key: Optional[jax.Array] = None,
    site=None,
) -> int:
    """Draw one token id from a (V,) logits row.

    ``site`` is the resolved ``serve/sampler`` SitePrecision (f32 in the
    base table); the filtering/softmax math runs at its compute dtype.
    Greedy requests never touch the PRNG key, so greedy streams are
    reproducible without seed plumbing.
    """
    logits = jnp.asarray(logits)
    if site is not None:
        logits = logits.astype(site.compute_dtype)
    if params.temperature <= 0.0:
        return int(jnp.argmax(logits, axis=-1))
    if key is None:
        raise ValueError("non-greedy sampling requires an explicit PRNG key")
    logits = logits / params.temperature
    if params.top_k:
        logits = apply_top_k(logits, params.top_k)
    if params.top_p < 1.0:
        logits = apply_top_p(logits, params.top_p)
    return int(jax.random.categorical(key, logits))


def request_key(base_key: jax.Array, uid: int, step: int) -> jax.Array:
    """The per-(request, generation-index) key: stable under slot
    reassignment and tick interleaving."""
    return jax.random.fold_in(jax.random.fold_in(base_key, uid), step)
