"""Auto-precision benchmark: steps-to-stable-policy, accuracy vs the
static registry policies, and telemetry overhead.

Trains the Darcy smoke problem three ways — static ``full``, static
``mixed_fno_bf16`` (the paper-faithful baseline) and ``auto``
(telemetry + bound-guided controller over a ``full`` base) — and records:

* **steps_to_stable**: the last training step at which the controller
  changed the overlay (afterwards the policy is converged);
* **final_loss** per run, plus the auto-vs-static ratio (acceptance:
  auto within 5% of ``mixed_fno_bf16``);
* **demoted_fraction**: how many spectral-contract sites the controller
  runs below fp32 (acceptance: >= half), and the overflow counters
  (acceptance: zero non-recovered overflows — every skipped step must be
  followed by recovery, and the counters record none here);
* **telemetry overhead**: median step wall-time with taps collected vs
  without, on the *same* static policy (acceptance: < 10%).

    PYTHONPATH=src python -m benchmarks.bench_autoprec [--steps 40]

Results land in ``benchmarks/results/autoprec.json``.
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.autoprec import AutoPrecisionController
from repro.core import PrecisionSchedule
from repro.models import fno_apply
from repro.train import Trainer, TrainerConfig, relative_l2

from benchmarks.common import darcy_data, small_fno, write_result

RESULTS = os.path.join(os.path.dirname(__file__), "results", "autoprec.json")


def _trainer(loss_fn, params, steps, *, schedule=None, autoprec=None,
             telemetry=False):
    cfg = TrainerConfig(
        total_steps=steps,
        schedule=schedule or PrecisionSchedule.constant("full"),
        autoprec=autoprec,
        telemetry=telemetry,
    )
    return Trainer(loss_fn, params, cfg)


def _median_step_time(history, skip: int = 3) -> float:
    """Median per-step wall time, skipping compile-bearing early steps."""
    ts = [h["dt"] for h in history[skip:]] or [h["dt"] for h in history]
    return float(np.median(ts))


def run(steps: int = 40, resolution: int = 32, interval: int = 5) -> dict:
    cfg, params = small_fno(hidden=16, modes=(8, 8))
    cfg_small = cfg
    (a, u), _ = darcy_data(n=resolution, ntrain=16, ntest=8, maxiter=200)

    def loss_fn(p, batch, policy):
        return relative_l2(fno_apply(p, batch["x"], cfg_small, policy),
                           batch["t"])

    batch_fn = lambda _step: {"x": a, "t": u}  # noqa: E731

    runs = {}
    # -- static baselines -----------------------------------------------------
    for name in ("full", "mixed_fno_bf16"):
        tr = _trainer(loss_fn, params, steps,
                      schedule=PrecisionSchedule.constant(name))
        hist = tr.run(batch_fn)
        runs[name] = {
            "final_loss": hist[-1]["loss"],
            "median_step_s": _median_step_time(hist),
            "skipped_steps": tr.stats["skipped_steps"],
        }

    # -- auto mode -------------------------------------------------------------
    ctl = AutoPrecisionController(base="full", grid_points=resolution ** 2,
                                  interval=interval)
    tr = _trainer(loss_fn, params, steps, autoprec=ctl)
    hist = tr.run(batch_fn)
    changes = [h["step"] for i, h in enumerate(hist[1:], 1)
               if h["policy"] != hist[i - 1]["policy"]]
    contract_sites = [f"fno/layer{i}/spectral/contract"
                      for i in range(cfg_small.n_layers)]
    pol = ctl.policy()
    demoted = [s for s in contract_sites if pol.at(s).compute is not None]
    telem = tr.telemetry.counters()
    runs["auto"] = {
        "final_loss": hist[-1]["loss"],
        "median_step_s": _median_step_time(hist),
        "policy": pol.name,
        "policy_changes": tr.stats["policy_changes"],
        "steps_to_stable": changes[-1] if changes else 0,
        "recompiles": tr.stats["recompiles"],
        "skipped_steps": tr.stats["skipped_steps"],
        "demoted_contract_sites": demoted,
        "demoted_fraction": len(demoted) / len(contract_sites),
        "overflow_total": telem["overflow_total"],
        "decisions": {g: s["fmt"]
                      for g, s in ctl.describe()["sites"].items()},
    }

    # -- telemetry overhead: same static policy, taps on vs off ---------------
    base = _trainer(loss_fn, params, steps,
                    schedule=PrecisionSchedule.constant("mixed_fno_bf16"))
    h_off = base.run(batch_fn)
    instr = _trainer(loss_fn, params, steps,
                     schedule=PrecisionSchedule.constant("mixed_fno_bf16"),
                     telemetry=True)
    h_on = instr.run(batch_fn)
    t_off = _median_step_time(h_off)
    t_on = _median_step_time(h_on)
    overhead = t_on / t_off - 1.0

    rel = runs["auto"]["final_loss"] / runs["mixed_fno_bf16"]["final_loss"] - 1.0
    report = {
        "steps": steps,
        "resolution": resolution,
        "runs": runs,
        "auto_vs_bf16_loss_rel": rel,
        "telemetry_overhead": overhead,
        "acceptance": {
            "loss_within_5pct": bool(abs(rel) <= 0.05),
            "half_contract_sites_below_fp32":
                runs["auto"]["demoted_fraction"] >= 0.5,
            "zero_overflows": runs["auto"]["overflow_total"] == 0
                and runs["auto"]["skipped_steps"] == 0,
            "telemetry_overhead_lt_10pct": bool(overhead < 0.10),
        },
    }
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--resolution", type=int, default=32)
    ap.add_argument("--interval", type=int, default=5)
    args = ap.parse_args()

    jax.config.update("jax_platform_name", "cpu")
    report = run(args.steps, args.resolution, args.interval)
    write_result(RESULTS, report)

    print(f"\n== bench_autoprec (steps={args.steps}, "
          f"res={args.resolution}) ==")
    for name, r in report["runs"].items():
        print(f"  {name:<16s} final_loss={r['final_loss']:.4f} "
              f"step={r['median_step_s']*1e3:.1f}ms")
    a = report["runs"]["auto"]
    print(f"  auto policy={a['policy']} decisions={a['decisions']}")
    print(f"  steps_to_stable={a['steps_to_stable']} "
          f"demoted_fraction={a['demoted_fraction']:.2f}")
    print(f"  auto vs bf16 loss: {report['auto_vs_bf16_loss_rel']:+.2%}")
    print(f"  telemetry overhead: {report['telemetry_overhead']:+.2%}")
    print(f"  acceptance: {report['acceptance']}")
    print(f"results -> {RESULTS}")
    return 0 if all(report["acceptance"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
