"""PagedLMEngine: the continuous-batching LM engine over a paged KV
cache — fixed-size blocks, copy-on-write, and prefix sharing.

The engine subclasses :class:`~repro.serve.engine.LMEngine` and swaps
only the cache/step layer: scheduling, chunked prefill, sampling and
accounting are inherited unchanged, so the paged path is the dense path
plus a block indirection.  Bit-identity is by construction — the paged
steps (:func:`~repro.models.lm.lm_paged_decode_step` /
``lm_paged_prefill_chunk``) gather each layer's dense view out of the
block arrays, run the *exact* dense attention step on it, and scatter
the written rows back.

Host-side protocol per tick (before the device step):

  1. map the ring rows this tick will write to logical blocks;
  2. allocate table entries still unbacked (queueing a ``kv_pos = -1``
     invalidation for the fresh block — its rows may be stale);
  3. copy-on-write any mapped block with refcount > 1 (prefix-shared or
     ring-wrapped), queueing one batched device copy;
  4. flush the queued invalidations/copies as one indexed update per
     array, refresh the device block table if the mapping changed.

Prefix sharing: at admission the prompt is walked through the
:class:`~repro.serve.paged.prefix.PrefixIndex`; every matched full
block is mapped (refcount++) and its tokens are *skipped* from prefill
— the slot starts at ``hits * block_size``.  At prefill completion the
prompt's full blocks are registered for future requests.  Restricted to
pure-attention, non-MoE archs: SSD recurrent state cannot be skipped
into, and MoE expert routing is batch-composition-dependent.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecisionPolicy, FULL
from repro.configs.base import LMArchConfig
from repro.models.lm import (
    init_paged_cache,
    lm_paged_decode_step,
    lm_paged_prefill_chunk,
)

from repro.obs import trace as obs_trace

from ..engine import LMEngine, Request
from .pool import BlockPool
from .prefix import PrefixIndex


class PagedLMEngine(LMEngine):
    kind = "lm_paged"

    def __init__(
        self,
        params,
        cfg: LMArchConfig,
        n_slots: int = 4,
        max_len: int = 512,
        policy: PrecisionPolicy = FULL,
        scheduler: str = "fcfs",
        prefill_chunk: Optional[int] = None,
        seed: int = 0,
        telemetry: bool = False,
        record_logits: bool = False,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        prefix_sharing: bool = True,
        mesh=None,
    ):
        if mesh is not None:
            raise ValueError(
                "PagedLMEngine is single-host (block tables are host "
                "state); use the dense LMEngine for mesh serving")
        self.block_size = block_size
        self._num_blocks_arg = num_blocks
        self._prefix_sharing = prefix_sharing
        # pure-SSD archs have no KV rows to page: the engine degrades to
        # the dense path (pool/prefix stay None, stats say so)
        self._paged = cfg.mixer in ("attn", "hymba")
        self.pool: Optional[BlockPool] = None
        self.prefix: Optional[PrefixIndex] = None
        self._prefix_ok = False
        self._pending_resets: List[int] = []
        self._pending_copies: List[Tuple[int, int]] = []
        self._peak_live_blocks = 0
        super().__init__(
            params, cfg, n_slots=n_slots, max_len=max_len, policy=policy,
            mesh=None, scheduler=scheduler, prefill_chunk=prefill_chunk,
            seed=seed, telemetry=telemetry, record_logits=record_logits)

    # -- build hooks -----------------------------------------------------------
    def _build_cache(self):
        if not self._paged:
            return super()._build_cache()
        W, bs = self._kv_len, self.block_size
        if W % bs:
            raise ValueError(
                f"block_size {bs} must divide the cache width {W} "
                f"(max_len, or the SWA window for ring caches)")
        self._nbt = W // bs
        # default: full backing for every slot + one table's worth of
        # spare blocks for prefix survivors + the reserved null block
        num_blocks = (self._num_blocks_arg
                      or self.n_slots * self._nbt + self._nbt + 1)
        self.pool = BlockPool(num_blocks, bs)
        self.prefix = PrefixIndex(self.pool) if self._prefix_sharing else None
        self._prefix_ok = (self._prefix_sharing and self.cfg.mixer == "attn"
                           and not self.cfg.moe_experts)
        # host block table: -1 = unbacked (device gathers the null block)
        self._bt = np.full((self.n_slots, self._nbt), -1, np.int64)
        self._bt_dev = None
        self._bt_dirty = True
        return init_paged_cache(
            self.cfg, self.n_slots, num_blocks, bs, self.max_len,
            dtype=self.policy.at("serve/paged/kv_blocks").compute_dtype)

    def _build_steps(self):
        if not self._paged:
            return super()._build_steps()
        cfg, policy = self.cfg, self.policy
        self._decode = jax.jit(
            lambda p, c, bt, act, t:
            lm_paged_decode_step(p, c, bt, act, t, cfg, policy))
        self._chunk = jax.jit(
            lambda p, c, bt, t, n:
            lm_paged_prefill_chunk(p, c, bt, t, n, cfg, policy))

    # -- block bookkeeping -----------------------------------------------------
    def _alloc_block(self) -> int:
        """A fresh exclusively-owned block, evicting LRU prefix entries
        under pool pressure.  Fresh blocks may hold a previous owner's
        rows, so their kv_pos is queued for invalidation."""
        assert self.pool is not None
        b = self.pool.alloc()
        if b is None and self.prefix is not None:
            self.prefix.evict_until(1)
            obs_trace.event("serve/paged/evict", category="paged",
                            tick=self._ticks)
            b = self.pool.alloc()
        if b is None:
            raise RuntimeError(
                f"KV block pool exhausted ({self.pool.num_blocks} blocks, "
                f"{len(self.prefix) if self.prefix else 0} prefix entries) "
                f"— raise num_blocks or lower n_slots/max_len")
        self._pending_resets.append(b)
        return b

    def _exclusive(self, block: int) -> int:
        """Copy-on-write: resolve exclusive ownership of ``block`` before
        this tick's write lands in it."""
        assert self.pool is not None
        if self.pool.refcount(block) == 1:
            return block
        if self.pool.free_blocks == 0 and self.prefix is not None:
            self.prefix.evict_until(1)
            obs_trace.event("serve/paged/evict", category="paged",
                            tick=self._ticks)
        dst, copy = self.pool.cow(block)
        if copy is not None:
            self._pending_copies.append(copy)
            obs_trace.event("serve/paged/cow", category="paged",
                            src=copy[0], dst=copy[1], tick=self._ticks)
        return dst

    def _prepare_writes(self, slot_rows: List[Tuple[int, List[int]]]):
        """Back every ring row written this tick with an exclusively
        owned block, then flush the queued device updates."""
        for i, rows in slot_rows:
            for j in sorted({r // self.block_size for r in rows}):
                b = int(self._bt[i, j])
                if b <= 0:
                    self._bt[i, j] = self._alloc_block()
                    self._bt_dirty = True
                else:
                    nb = self._exclusive(b)
                    if nb != b:
                        self._bt[i, j] = nb
                        self._bt_dirty = True
        self._flush_block_updates()
        self._peak_live_blocks = max(self._peak_live_blocks,
                                     self.pool.live_blocks)

    def _flush_block_updates(self):
        """One batched indexed update per cache array for all of this
        tick's COW copies and fresh-block invalidations."""
        if not (self._pending_copies or self._pending_resets):
            return
        c = dict(self.cache)
        if self._pending_copies:
            srcs = np.asarray([s for s, _ in self._pending_copies], np.int32)
            dsts = np.asarray([d for _, d in self._pending_copies], np.int32)
            for k in ("k", "v", "c_kv", "k_rope", "kv_pos"):
                if k in c:
                    c[k] = c[k].at[:, dsts].set(c[k][:, srcs])
            self._pending_copies = []
        if self._pending_resets:
            ids = np.asarray(self._pending_resets, np.int32)
            c["kv_pos"] = c["kv_pos"].at[:, ids].set(-1)
            self._pending_resets = []
        self.cache = c

    def _block_table_dev(self) -> jnp.ndarray:
        if self._bt_dirty or self._bt_dev is None:
            self._bt_dev = jnp.asarray(
                np.where(self._bt < 0, 0, self._bt).astype(np.int32))
            self._bt_dirty = False
        return self._bt_dev

    # -- engine hooks ----------------------------------------------------------
    def _admit_slot(self, i: int, req: Request) -> int:
        if not (self._paged and self._prefix_ok and self.prefix is not None):
            return 0
        # cap: a fully-cached prompt must still leave >= 1 token so the
        # first generation has logits to come from
        max_blocks = min((len(req.prompt) - 1) // self.block_size, self._nbt)
        if max_blocks <= 0:
            return 0
        hit = self.prefix.lookup(req.prompt, self.block_size, max_blocks,
                                 self._ticks)
        for j, b in enumerate(hit):
            self._bt[i, j] = b
        if hit:
            self._bt_dirty = True
            obs_trace.event("serve/paged/prefix_hit", category="paged",
                            uid=req.uid, blocks=len(hit),
                            tokens=len(hit) * self.block_size)
        return len(hit) * self.block_size

    def _reset_slots(self, admitted: List[Tuple[int, int]]):
        if not self._paged:
            return super()._reset_slots(admitted)
        # per-slot state only: block invalidation is per *block*, done at
        # allocation time (kv_pos here is block-indexed, not slot-indexed)
        ids = np.asarray([i for i, _ in admitted], np.int32)
        starts = np.asarray([s for _, s in admitted], np.int32)
        c = dict(self.cache)
        c["step"] = c["step"].at[ids].set(starts)
        if "ssd_state" in c:
            c["ssd_state"] = c["ssd_state"].at[:, ids].set(0.0)
        self.cache = c

    def _release_slot(self, i: int):
        if not self._paged or self.pool is None:
            return
        for j in range(self._nbt):
            b = int(self._bt[i, j])
            if b > 0:
                self.pool.release(b)
        self._bt[i, :] = -1
        self._bt_dirty = True

    def _on_prefill_complete(self, i: int, req: Request):
        if not (self._paged and self._prefix_ok and self.prefix is not None):
            return
        P = len(req.prompt)
        # register only full blocks of prompts that never wrapped the
        # ring (wrapped blocks hold later positions than their index)
        if P < self.block_size or P > self._kv_len:
            return
        blocks = [int(self._bt[i, j]) for j in range(P // self.block_size)]
        if any(b <= 0 for b in blocks):
            return
        self.prefix.register(req.prompt, blocks, self.block_size, self._ticks)

    # -- device steps ----------------------------------------------------------
    def _run_decode(self, tokens: np.ndarray) -> np.ndarray:
        if not self._paged:
            return super()._run_decode(tokens)
        active = np.asarray([s is not None for s in self.slots])
        slot_rows = [(i, [self.slot_pos[i] % self._kv_len])
                     for i, s in enumerate(self.slots) if s is not None]
        self._prepare_writes(slot_rows)
        logits, self.cache = self._decode(
            self.params, self.cache, self._block_table_dev(),
            jnp.asarray(active), jnp.asarray(tokens))
        return np.asarray(logits)

    def _run_chunk(self, tokens: np.ndarray, n_valid: np.ndarray) -> np.ndarray:
        if not self._paged:
            return super()._run_chunk(tokens, n_valid)
        slot_rows = []
        for i, s in enumerate(self.slots):
            k = int(n_valid[i])
            if s is None or k == 0:
                continue
            slot_rows.append(
                (i, [(self.slot_pos[i] + t) % self._kv_len for t in range(k)]))
        self._prepare_writes(slot_rows)
        logits, self.cache = self._chunk(
            self.params, self.cache, self._block_table_dev(),
            jnp.asarray(tokens), jnp.asarray(n_valid))
        return np.asarray(logits)

    # -- stats -----------------------------------------------------------------
    def _fragmentation(self) -> float:
        """Internal fragmentation: unused rows inside slot-mapped blocks
        as a fraction of their capacity (paged blocks never fragment
        externally — any free block serves any request)."""
        slot_blocks = int((self._bt > 0).sum())
        if not slot_blocks:
            return 0.0
        used = sum(min(self.slot_pos[i], self._kv_len)
                   for i, s in enumerate(self.slots) if s is not None)
        return max(0.0, 1.0 - used / (slot_blocks * self.block_size))

    def _extra_stats(self) -> Dict[str, Any]:
        out = super()._extra_stats()
        if not self._paged or self.pool is None:
            out["paged"] = {"active": False,
                            "reason": f"{self.cfg.mixer} arch has no KV rows"}
            return out
        paged = {
            "active": True,
            **self.pool.stats(),
            "blocks_per_slot": self._nbt,
            "peak_live_blocks": self._peak_live_blocks,
            "fragmentation": round(self._fragmentation(), 4),
            "prefix": self.prefix.stats() if self.prefix is not None
            else {"enabled": False},
        }
        out["paged"] = paged
        if self._telemetry_on:
            # pool gauges through the autoprec tap (no-op unless a
            # collector is in scope, like every other telemetry site)
            from repro.autoprec.telemetry import tap
            tap("serve/paged/pool", jnp.asarray(
                [self.pool.occupancy, paged["fragmentation"],
                 float(self.pool.cow_copies)], jnp.float32))
        return out

    def _reset_extra_counters(self) -> None:
        super()._reset_extra_counters()
        self._peak_live_blocks = (
            self.pool.live_blocks if self.pool is not None else 0)
